"""SU(3) gauge-link compression codecs.

Locks down the two compressed link representations against the full
18-real storage, at both the complex matrix level (``repro.core.su3``)
and the planar kernel-layout level (``repro.kernels.layout``):

* ``two_row``  — 12 reals: drop the third row, reconstruct it as the
  complex-conjugate cross product of the first two (exact up to
  rounding: one fused cross product per link);
* ``minimal``  — 8 reals: additionally collapse the first row and the
  reconstructed-row anchor to two phases plus magnitudes recovered from
  unitarity (amplifies rounding through sqrt/atan2 — looser f32 bound).

Also pins the byte/flop accounting the bandwidth story quotes: the
VMEM headroom compressed links free up, the policy boundary shift it
causes, and the halo/HBM traffic-model scaling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import su3
from repro.kernels import layout, wilson_stencil as ws
from repro.distributed import halo

SHAPE = (3, 4, 2, 3)        # small (T, Z, Y, Xh) worth of links


def _links(n=64, seed=0, dtype=jnp.complex64):
    # Generate at the target precision: reconstruction relies on the
    # links being *unitary at that precision*, so upcasting f32 links
    # to f64 would cap the round-trip accuracy at the f32 defect.
    U = su3.random_gauge(jax.random.PRNGKey(seed), (1, 1, 2, 2 * n),
                         dtype=dtype)
    return U.reshape(-1, 3, 3)[:n]


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


# --- complex-matrix codecs -------------------------------------------


def test_two_row_roundtrip_f32():
    U = _links()
    W = su3.compress_two_row(U)
    assert W.shape == U.shape[:-2] + (2, 3)
    err = float(jnp.max(jnp.abs(su3.reconstruct_two_row(W) - U)))
    assert err <= 1e-6, err


def test_two_row_roundtrip_f64():
    with _x64():
        U = _links(dtype=jnp.complex128)
        W = su3.compress_two_row(U)
        R = su3.reconstruct_two_row(W)
        assert R.dtype == jnp.complex128
        err = float(jnp.max(jnp.abs(R - U)))
        assert err <= 1e-12, err


def test_minimal_roundtrip_f32():
    U = _links()
    W = su3.compress_minimal(U)
    assert W.shape == U.shape[:-2] + (8,)
    assert not jnp.iscomplexobj(W)
    err = float(jnp.max(jnp.abs(su3.reconstruct_minimal(W) - U)))
    assert err <= 1e-4, err


def test_minimal_roundtrip_f64():
    with _x64():
        U = _links(dtype=jnp.complex128)
        W = su3.compress_minimal(U)
        R = su3.reconstruct_minimal(W, dtype=jnp.complex128)
        err = float(jnp.max(jnp.abs(R - U)))
        assert err <= 1e-9, err


def test_reconstructed_links_stay_unitary():
    U = _links(seed=3)
    for R in (su3.reconstruct_two_row(su3.compress_two_row(U)),
              su3.reconstruct_minimal(su3.compress_minimal(U))):
        eye = jnp.eye(3, dtype=R.dtype)
        defect = float(jnp.max(jnp.abs(
            jnp.einsum("...ij,...kj->...ik", R, R.conj()) - eye)))
        assert defect <= 5e-5, defect


# --- planar-layout codecs --------------------------------------------


@pytest.mark.parametrize("mode,comps", [("none", 18), ("two_row", 12),
                                        ("minimal", 8)])
def test_planar_codec_shapes(mode, comps):
    U = su3.random_gauge(jax.random.PRNGKey(1), SHAPE)
    c = layout.gauge_compress_planar(layout.gauge_to_planar(U), mode)
    assert c.shape[-3] == comps
    x = layout.gauge_expand_planar(c) if mode != "none" else c
    assert x.shape[-3] == layout.GAUGE_COMPS


@pytest.mark.parametrize("mode,atol32,atol64", [
    ("two_row", 1e-6, 1e-12),
    ("minimal", 1e-4, 1e-9),
])
def test_planar_codec_roundtrip(mode, atol32, atol64):
    U = su3.random_gauge(jax.random.PRNGKey(2), SHAPE)
    p32 = layout.gauge_to_planar(U, jnp.float32)
    got = layout.gauge_expand_planar(
        layout.gauge_compress_planar(p32, mode))
    np.testing.assert_allclose(np.asarray(got), np.asarray(p32),
                               atol=atol32)
    with _x64():
        U64 = su3.random_gauge(jax.random.PRNGKey(2), SHAPE,
                               dtype=jnp.complex128)
        p64 = layout.gauge_to_planar(U64, jnp.float64)
        got = layout.gauge_expand_planar(
            layout.gauge_compress_planar(p64, mode))
        np.testing.assert_allclose(np.asarray(got), np.asarray(p64),
                                   atol=atol64)


def test_planar_codec_bf16_loose():
    """bf16 planar links survive the codec within bf16 resolution (the
    compressed representation must not blow up at 8-bit mantissas)."""
    U = su3.random_gauge(jax.random.PRNGKey(4), SHAPE)
    p = layout.gauge_to_planar(U, jnp.bfloat16)
    for mode in ("two_row", "minimal"):
        got = layout.gauge_expand_planar(
            layout.gauge_compress_planar(p, mode))
        assert got.dtype == jnp.bfloat16
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - p.astype(jnp.float32))))
        assert err <= 0.1, (mode, err)


def test_gauge_from_planar_auto_expands():
    """The planar->complex decoder accepts compressed planes directly —
    the round trip through compression lands on the same gauge field."""
    U = su3.random_gauge(jax.random.PRNGKey(5), SHAPE)
    p = layout.gauge_to_planar(U, jnp.float32)
    want = layout.gauge_from_planar(p)
    for mode, atol in (("two_row", 1e-6), ("minimal", 1e-4)):
        got = layout.gauge_from_planar(
            layout.gauge_compress_planar(p, mode))
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=atol)


def test_compress_planar_rejects_unknown_mode():
    p = jnp.zeros((4, 2, 2, 18, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="gauge compression"):
        layout.gauge_compress_planar(p, "three_row")


def test_expand_links_planes_noop_at_full_width():
    u = jnp.ones((18, 4, 4), jnp.float32)
    assert layout.expand_links_planes(u) is u


# --- byte/flop accounting --------------------------------------------


def test_gauge_headroom_and_fits_boundary():
    """Compressed links extend the fused-kernel VMEM budget by exactly
    the bytes they free in the double-buffered gauge window; gc=18 is a
    strict no-op on every policy boundary."""
    Y, Xh, itemsize = 4, 4, 4
    assert ws.gauge_headroom_bytes(Y, Xh, itemsize, gauge_comps=18) == 0
    for gc in (12, 8):
        head = ws.gauge_headroom_bytes(Y, Xh, itemsize, gauge_comps=gc)
        assert head == (18 - gc) * 12 * 2 * Y * Xh * itemsize
        limit = ws._FUSED_SCRATCH_LIMIT_BYTES
        row = itemsize * 4 * 24 * Y * Xh
        T_plain = limit // row
        T_gc = (limit + head) // row
        assert T_gc > T_plain     # the cap actually moved
        shape = (T_gc, 4, 24, Y, Xh)
        assert ws.fused_dhat_fits(shape, jnp.float32, gauge_comps=gc)
        assert not ws.fused_dhat_fits(shape, jnp.float32)
        assert ws.fused_dhat_policy(shape, jnp.float32,
                                    gauge_comps=gc) == "resident"


def test_hop_traffic_model_scales_with_compression():
    m18 = ws.hop_traffic_model(8, 8, 8, 4)
    m12 = ws.hop_traffic_model(8, 8, 8, 4, gauge_comps=12)
    m8 = ws.hop_traffic_model(8, 8, 8, 4, gauge_comps=8)
    assert m12["bytes_gauge"] * 18 == m18["bytes_gauge"] * 12
    assert m8["bytes_gauge"] * 18 == m18["bytes_gauge"] * 8
    # Spinor traffic is untouched; reconstruction flops are accounted.
    assert m12["bytes_spinor"] == m18["bytes_spinor"]
    assert m18["flops_recon"] == 0
    assert m12["flops_recon"] == 42 * 8 * 8 * 8 * 8 * 4
    assert m8["flops_recon"] > m12["flops_recon"]
    assert m12["flops"] == m18["flops"] + m12["flops_recon"]


def test_halo_traffic_model_scales_with_compression():
    m18 = halo.halo_traffic_model(4, 4, 4, 4)
    m12 = halo.halo_traffic_model(4, 4, 4, 4, gauge_comps=12)
    m8 = halo.halo_traffic_model(4, 4, 4, 4, gauge_comps=8)
    assert m12["bytes_gauge_exchange"] * 3 == m18["bytes_gauge_exchange"] * 2
    assert m8["bytes_gauge_exchange"] * 9 == m18["bytes_gauge_exchange"] * 4
    assert m12["bytes_spinor_exchange"] == m18["bytes_spinor_exchange"]
    for m in (m18, m12, m8):
        assert m["bytes_dhat_exchange"] == 2 * m["bytes_hop_exchange"]
