"""Substrate tests: checkpointing (atomic/last-k/reshard), data pipeline
determinism, optimizer behavior, gradient accumulation equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.data.pipeline import BinaryShards, DataConfig, SyntheticLM
from repro.optim import adamw


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    ck.save(7, tree, extras={"cursor": 7})
    out, step, extras = ck.restore(tree)
    assert step == 7 and extras["cursor"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    tree = {"x": jnp.zeros((4,))}
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.full((4,), float(s))})
    assert ck.latest_step() == 4
    assert sorted(ck.all_steps()) == [3, 4]
    out, step, _ = ck.restore(tree)
    assert float(out["x"][0]) == 4.0


def test_checkpoint_async_and_crash_safety(tmp_path):
    tree = {"x": jnp.ones((8, 8))}
    ck = Checkpointer(str(tmp_path), keep=3, async_save=True)
    ck.save(1, tree)
    ck.wait()
    # simulate a crashed save: stale staging dir must not break restore
    (tmp_path / ".tmp_step_000000099").mkdir()
    out, step, _ = ck.restore(tree)
    assert step == 1


def test_checkpoint_restore_resharded(tmp_path):
    """Restore places leaves with the given shardings (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, tree)
    mesh = compat.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _, _ = ck.restore(tree, shardings=sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)


def test_synthetic_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    src = SyntheticLM(cfg)
    b1, b2 = src.batch(5), src.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_binary_shards_roundtrip(tmp_path):
    toks = np.random.default_rng(0).integers(0, 1000, size=10000)
    path = tmp_path / "shard.bin"
    BinaryShards.write(str(path), toks)
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4,
                     path=str(path))
    src = BinaryShards(cfg)
    b = src.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 1000
    np.testing.assert_array_equal(src.batch(3)["tokens"],
                                  src.batch(3)["tokens"])


def test_adamw_reduces_quadratic():
    opt = adamw.AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    st = opt.init(params)
    for i in range(150):
        grads = {"w": 2 * params["w"]}
        up, st = opt.update(grads, st, params, jnp.int32(i))
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, up)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip():
    opt = adamw.AdamW(clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    st = opt.init(g)
    up, _ = opt.update(g, st, {"w": jnp.zeros((4,))}, jnp.int32(0))
    assert np.isfinite(np.asarray(up["w"])).all()


def test_grad_accum_equivalence():
    """accum=2 over a batch == accum=1 on the same batch (same grads up
    to f32 noise -> same loss metric and very close params)."""
    from conftest import build_small
    from repro.models import model as M, steps as steps_lib

    c = build_small("minitron-4b", n_layers=2)
    p = M.init_params(c, jax.random.PRNGKey(0))
    opt = adamw.AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    st = opt.init(p)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                          0, c.vocab_size),
             "mask": jnp.ones((4, 16), jnp.float32)}
    s1 = steps_lib.make_train_step(c, opt, remat=False, accum_steps=1)
    s2 = steps_lib.make_train_step(c, opt, remat=False, accum_steps=2)
    p1, _, m1 = s1(p, st, batch, jnp.int32(0))
    p2, _, m2 = s2(p, st, batch, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
