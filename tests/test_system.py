"""End-to-end behaviour tests for the paper's system: the even-odd Wilson
solve pipeline from gauge field to verified solution, through the public
API, including the Pallas-backed path and checkpoint/restart."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import api, configs
from repro.core import evenodd, su3, wilson
from repro.kernels import ops


def test_end_to_end_solve_paper_pipeline():
    """The full pipeline of the paper: random gauge -> even-odd pack ->
    Schur-preconditioned Krylov solve -> reconstruct -> verify."""
    lat = configs.get_qcd("wilson-16x16x16x16")
    # shrink to CI size but keep the pipeline identical
    T, Z, Y, X = 8, 8, 8, 8
    kappa = lat.kappa
    U = su3.random_gauge(jax.random.PRNGKey(0), (T, Z, Y, X))
    eta = (jax.random.normal(jax.random.PRNGKey(1), (T, Z, Y, X, 4, 3))
           + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                    (T, Z, Y, X, 4, 3))
           ).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    ee, eo = evenodd.pack(eta)
    xe, xo, res = api.solve(Ue, Uo, ee, eo, kappa,
                            spec=api.SolveSpec(method="bicgstab",
                                               tol=1e-6))
    xi = evenodd.unpack(xe, xo)
    rel = float(jnp.linalg.norm(eta - wilson.apply_wilson(U, xi, kappa))
                / jnp.linalg.norm(eta))
    assert rel < 1e-5
    assert int(res.iterations) < 100


def test_solver_driver_cli(tmp_path, capsys):
    """The launch/solve.py driver runs end to end with checkpointing."""
    from repro.launch import solve

    solve.main(["--lattice", "wilson-16x16x16x16", "--tol", "1e-5",
                "--ckpt-dir", str(tmp_path), "--n-solves", "1"])
    out = capsys.readouterr().out
    assert "solve 0:" in out and "done" in out
    from repro.checkpoint.ckpt import Checkpointer
    assert Checkpointer(str(tmp_path)).latest_step() == 0


def test_kernel_backend_equals_jnp_backend_end_to_end(small_lattice,
                                                      small_eo):
    """Dhat through the Pallas kernel == through pure jnp, applied twice
    (operator composition stability)."""
    U, _, kappa = small_lattice
    Ue, Uo, e, _, _ = small_eo
    from repro.kernels import layout, ref

    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    a = ops.apply_dhat_planar(Uep, Uop, ep, kappa, interpret=True)
    a = ops.apply_dhat_planar(Uep, Uop, a, kappa, interpret=True)
    b = ref.apply_dhat_planar_ref(Uep, Uop, ep, kappa)
    b = ref.apply_dhat_planar_ref(Uep, Uop, b, kappa)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_arch_registry_complete():
    assert len(configs.ARCH_NAMES) == 10
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        cells = configs.shapes_for(cfg)
        assert len(cells) == 4
        # long_500k must run for subquadratic archs, skip for the rest
        long_skip = dict((c.name, s) for c, s in cells)["long_500k"]
        if cfg.subquadratic:
            assert long_skip is None
        else:
            assert long_skip is not None


def test_shape_cells_constants():
    s = configs.SHAPE_BY_NAME
    assert s["train_4k"].seq_len == 4096 and \
        s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768 and \
        s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].seq_len == 32768 and \
        s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288 and \
        s["long_500k"].global_batch == 1
