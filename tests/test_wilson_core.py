"""Full-lattice Wilson operator and the even-odd decomposition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import evenodd, su3, wilson


def test_su3_unitarity_and_det(small_lattice):
    U, _, _ = small_lattice
    assert float(su3.unitarity_defect(U)) < 5e-6
    det = jnp.linalg.det(U)
    np.testing.assert_allclose(np.asarray(jnp.abs(det)), 1.0, atol=1e-5)


def test_plaquette_unit_gauge():
    P = su3.plaquette(su3.unit_gauge((4, 4, 4, 4)))
    assert abs(float(P) - 1.0) < 1e-6


def test_plaquette_gauge_invariance(small_lattice):
    """Plaquette is invariant under a random gauge transformation."""
    U, _, _ = small_lattice
    shape = U.shape[1:5]
    g = su3.random_su3(jax.random.PRNGKey(7), shape)
    from repro.core.lattice import shift
    Ut = jnp.stack([
        jnp.einsum("...ab,...bc,...dc->...ad", g, U[mu],
                   shift(g, mu, +1).conj())
        for mu in range(4)])
    p0, p1 = su3.plaquette(U), su3.plaquette(Ut)
    assert abs(float(p0) - float(p1)) < 1e-5


def test_gamma5_hermiticity(small_lattice):
    U, psi, kappa = small_lattice
    chi = jnp.roll(psi, 3, axis=1) * (0.7 + 0.2j)
    lhs = jnp.vdot(chi, wilson.apply_wilson(U, psi, kappa))
    rhs = jnp.vdot(wilson.apply_wilson_dagger(U, chi, kappa), psi)
    assert abs(complex(lhs - rhs)) / abs(complex(lhs)) < 1e-4


def test_free_field_dispersion():
    """With unit gauge and kappa = 1/8, D_W annihilates the constant mode
    (massless free fermion)."""
    shape = (4, 4, 4, 4)
    U = su3.unit_gauge(shape)
    psi = jnp.ones((*shape, 4, 3), jnp.complex64)
    out = wilson.apply_wilson(U, psi, 1.0 / 8.0)
    assert float(jnp.max(jnp.abs(out))) < 1e-5


def test_pack_unpack_roundtrip(small_lattice):
    _, psi, _ = small_lattice
    e, o = evenodd.pack(psi)
    assert e.shape[3] == psi.shape[3] // 2
    np.testing.assert_array_equal(np.asarray(evenodd.unpack(e, o)),
                                  np.asarray(psi))


def test_pack_parity_correct(small_lattice):
    """Every element of the even array has even site parity."""
    _, psi, _ = small_lattice
    from repro.core.lattice import site_parity
    par = np.asarray(site_parity(psi.shape[:4]))
    e, o = evenodd.pack(psi)
    pe, po = evenodd.pack(jnp.asarray(
        par[..., None, None] * jnp.ones_like(psi.real)))
    assert np.all(np.asarray(pe) == 0)
    assert np.all(np.asarray(po) == 1)


@pytest.mark.parametrize("parity", [evenodd.EVEN, evenodd.ODD])
def test_hop_block_vs_oracle(small_eo, small_lattice, parity):
    U, _, _ = small_lattice
    Ue, Uo, e, o, _ = small_eo
    src = e if parity == evenodd.ODD else o
    native = evenodd.hop_block(Ue, Uo, src, parity)
    oracle = evenodd.hop_block_oracle(U, src, parity)
    np.testing.assert_allclose(np.asarray(native), np.asarray(oracle),
                               atol=1e-5)


def test_eo_reproduces_full_wilson(small_lattice, small_eo):
    U, psi, kappa = small_lattice
    Ue, Uo, e, o, _ = small_eo
    de, do = evenodd.apply_wilson_eo(Ue, Uo, e, o, kappa)
    fe, fo = evenodd.pack(wilson.apply_wilson(U, psi, kappa))
    np.testing.assert_allclose(np.asarray(de), np.asarray(fe), atol=1e-5)
    np.testing.assert_allclose(np.asarray(do), np.asarray(fo), atol=1e-5)


def test_hop_block_ext_periodic_halo(small_eo):
    """hop_block_ext with manually built periodic halos == hop_block."""
    Ue, Uo, e, o, _ = small_eo

    def ext(a, t, z):
        a = jnp.concatenate([a.take(jnp.array([-1]), axis=t), a,
                             a.take(jnp.array([0]), axis=t)], axis=t)
        return jnp.concatenate([a.take(jnp.array([-1]), axis=z), a,
                                a.take(jnp.array([0]), axis=z)], axis=z)

    want = evenodd.hop_block(Ue, Uo, e, evenodd.ODD)
    got = evenodd.hop_block_ext(Uo, ext(Ue, 1, 2), ext(e, 0, 1),
                                evenodd.ODD)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_dhat_definition(small_eo):
    Ue, Uo, e, _, kappa = small_eo
    got = evenodd.apply_dhat(Ue, Uo, e, kappa)
    want = e - kappa ** 2 * evenodd.hop_eo(
        Ue, Uo, evenodd.hop_oe(Ue, Uo, e))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
